"""Interpreter vs compiled-backend throughput on the four QNN workloads.

For each workload: run the optimized graph through the per-node numpy
interpreter (``Graph.execute``) and through the compiled backend
(``SiraModel.compile()`` — jitted JAX routed through the kernel wrappers;
jnp reference path on CPU, Pallas on TPU), on the same batched inputs,
and record per-sample latency + speedup.

The TFC row additionally carries the **tracer-overhead guard**: the
compiled path is re-timed with the ``repro.obs`` tracer enabled, and the
run aborts if enabled tracing costs more than 5% vs disabled — tracing
must never poison the dispatch-bound numbers (``trace_off_on_ratio`` is
gated in ``scripts/check_bench.py`` with a 0.95 hard floor).

Artifacts are routed through :func:`repro.obs.metrics.export_bench`, so
alongside ``BENCH_backend.json`` (schema unchanged) a Prometheus
text-format ``BENCH_backend.prom`` is written from the same registry.

    PYTHONPATH=src python benchmarks/bench_backend.py \
        [--batch 64] [--repeat 5] [--quick] [--out BENCH_backend.json] \
        [--trace trace_backend.json]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

#: workload whose compiled path carries the tracer-overhead guard — the
#: smallest (dispatch-bound) graph, where per-call overhead shows first
TRACE_GUARD_WORKLOAD = "TFC-w2a2"
TRACE_OVERHEAD_LIMIT = 1.05
#: the guard always measures at this batch regardless of --quick: the
#: 5% limit is calibrated against a ~100 us call — at tiny batches the
#: call shrinks toward pure dispatch and the same fixed tracer cost
#: would read as a limit-breaking percentage on every machine
TRACE_GUARD_BATCH = 64


def _time(fn, repeat: int) -> float:
    fn()                                 # warmup (trace/compile)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _trace_overhead(compiled, feeds, repeat: int) -> float:
    """Best-of-N compiled-path time ratio disabled/enabled tracer.

    Returns ``disabled_s / enabled_s`` (1.0 = free, < 1.0 = enabled is
    slower).  The compiled TFC call is ~100 us, so best-of-small-N is
    noise; worse, a single disabled-then-enabled pass is one-sided — a
    scheduler blip during the enabled half reads as tracer overhead.
    Interleave several rounds and keep each side's global best."""
    from repro.obs.trace import disable_tracing, enable_tracing

    n = max(repeat, 20)
    disabled_s = enabled_s = float("inf")
    for _ in range(3):
        disabled_s = min(disabled_s, _time(lambda: compiled(feeds), n))
        tracer = enable_tracing()
        try:
            enabled_s = min(enabled_s, _time(lambda: compiled(feeds), n))
        finally:
            disable_tracing()
        del tracer
    return disabled_s / enabled_s


def bench_workload(name: str, batch: int, repeat: int) -> dict:
    from repro.core import build_flow
    from repro.core.workloads import WORKLOADS

    model = build_flow(WORKLOADS[name]()).model
    (inp,) = model.graph.inputs
    shape = (batch,) + tuple(model.metadata["input_shape"][1:])
    r = model.input_ranges[inp]
    rng = np.random.default_rng(0)
    lo = np.broadcast_to(np.asarray(r.lo, np.float64), shape)
    hi = np.broadcast_to(np.asarray(r.hi, np.float64), shape)
    x = rng.uniform(lo, hi, size=shape)
    feeds = {inp: x}

    interp_s = _time(lambda: model.execute(feeds), repeat)
    compiled = model.compile()
    compiled_s = _time(lambda: compiled(feeds), repeat)

    row = dict(
        workload=name,
        batch=batch,
        nodes=len(model.graph.nodes),
        plan=compiled.kernel_calls,
        interpreter_us_per_sample=interp_s / batch * 1e6,
        compiled_us_per_sample=compiled_s / batch * 1e6,
        speedup=interp_s / compiled_s,
    )
    if name == TRACE_GUARD_WORKLOAD:
        gshape = (TRACE_GUARD_BATCH,) + shape[1:]
        gfeeds = {inp: rng.uniform(np.broadcast_to(lo[:1], gshape),
                                   np.broadcast_to(hi[:1], gshape),
                                   size=gshape)}
        ratio = _trace_overhead(compiled, gfeeds, repeat)
        row["trace_off_on_ratio"] = ratio
        if ratio < 1.0 / TRACE_OVERHEAD_LIMIT:
            raise AssertionError(
                f"enabled-tracer overhead on the compiled {name} path is "
                f"{(1 / ratio - 1):.1%} (> "
                f"{TRACE_OVERHEAD_LIMIT - 1:.0%} limit) — the obs "
                f"instrumentation leaked work into the disabled hot path")
    return row


def _write_trace(path: str, workload: str, batch: int) -> None:
    """One fully traced flow+compile+call, exported as Chrome JSON."""
    from repro.core import build_flow
    from repro.core.workloads import WORKLOADS
    from repro.obs.trace import disable_tracing, enable_tracing

    tracer = enable_tracing()
    try:
        model = build_flow(WORKLOADS[workload]()).model
        compiled = model.compile()
        feeds = next(model.sample_inputs())
        compiled(feeds)
        tracer.write_chrome_trace(path)
    finally:
        disable_tracing()
    print(f"wrote {path} ({len(tracer.spans)} spans)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--repeat", type=int, default=5)
    ap.add_argument("--quick", action="store_true",
                    help="small batch (CI smoke); keeps enough repeats "
                         "that best-of-N is stable — the tiny dispatch-"
                         "bound workloads (TFC) need ~20 samples for the "
                         "regression gate to be meaningful")
    ap.add_argument("--out", default="BENCH_backend.json")
    ap.add_argument("--trace", default=None, metavar="TRACE_JSON",
                    help="also run one fully traced TFC flow+compile+"
                         "call and write the Chrome trace_event JSON "
                         "(loadable in Perfetto) to this path")
    args = ap.parse_args()
    if args.quick:
        args.batch, args.repeat = 8, 20

    from repro.core.workloads import WORKLOADS
    from repro.obs.metrics import export_bench

    results = []
    for name in WORKLOADS:
        row = bench_workload(name, args.batch, args.repeat)
        results.append(row)
        print(f"{name:10s} batch={row['batch']:3d} "
              f"interp={row['interpreter_us_per_sample']:9.1f} us/sample "
              f"compiled={row['compiled_us_per_sample']:9.1f} us/sample "
              f"speedup={row['speedup']:6.1f}x", flush=True)
    import jax
    payload = dict(backend=jax.default_backend(),
                   batch=args.batch, repeat=args.repeat,
                   results=results)
    export_bench(payload, args.out, key=("workload",))
    print(f"wrote {args.out} (+ Prometheus text next to it)")
    if args.trace:
        _write_trace(args.trace, TRACE_GUARD_WORKLOAD, args.batch)


if __name__ == "__main__":
    main()
