"""Kernel micro-benchmarks (jnp reference path on CPU — wall-clock here is
indicative only; the Pallas kernels target TPU and are validated in
interpret mode).  Derived columns report the structural quantities that
matter on TPU: HBM bytes per call and arithmetic intensity."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

Row = Tuple[str, float, str]


def _timeit(fn, n=5) -> float:
    fn()().block_until_ready() if callable(fn()) else None
    t0 = time.perf_counter()
    for _ in range(n):
        r = fn()
        jax.block_until_ready(r)
    return (time.perf_counter() - t0) / n * 1e6


def kernel_benchmarks() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)

    for m, k, n in [(512, 512, 512), (1024, 1024, 1024)]:
        x = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        s = jnp.asarray(rng.uniform(0.01, 0.1, (n,)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
        f = jax.jit(lambda x, w, s, b: ref.int_matmul_ref(x, w, s, b))
        us = _timeit(lambda: f(x, w, s, b))
        flops = 2 * m * k * n
        byts = m * k + k * n + m * n * 4
        rows.append((f"int_matmul_{m}x{k}x{n}", us,
                     f"flops={flops};bytes={byts};"
                     f"ai={flops / byts:.1f}"))

    for n_thr in (15, 255):
        x = jnp.asarray(rng.integers(-2000, 2000, (4096, 512)), jnp.int32)
        thr = jnp.asarray(np.sort(rng.integers(-1500, 1500,
                                               (n_thr, 512)), 0), jnp.int32)
        f = jax.jit(lambda x, t: ref.multithreshold_ref(x, t))
        us = _timeit(lambda: f(x, thr))
        byts = x.size * 4 + thr.size * 4 + x.size
        rows.append((f"multithreshold_N{n_thr}", us,
                     f"bytes={byts};cmp_per_elem={n_thr}"))
        f2 = jax.jit(lambda x, t: ref.multithreshold_searchsorted_ref(x, t))
        us2 = _timeit(lambda: f2(x, thr))
        rows.append((f"multithreshold_bisect_N{n_thr}", us2,
                     f"bytes={byts};cmp_per_elem={int(np.log2(n_thr + 1))}"))

    x = jnp.asarray(rng.normal(size=(4096, 512)), jnp.float32)
    s = jnp.asarray(rng.uniform(0.01, 0.1, (512,)), jnp.float32)
    z = jnp.zeros((512,), jnp.float32)
    f = jax.jit(lambda x, s, z: ref.quantize_ref(x, s, z))
    us = _timeit(lambda: f(x, s, z))
    rows.append(("quantize_4096x512", us,
                 f"bytes={x.size * 4 + x.size}"))

    # fused vs unfused layer tail (the §5.2/§5.3 comparison, TPU economics)
    acc = jnp.asarray(rng.integers(-4000, 4000, (8192, 512)), jnp.int32)
    scale = jnp.asarray(rng.uniform(0.001, 0.01, (512,)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(512,)), jnp.float32)

    def composite(acc):
        y = acc.astype(jnp.float32) * scale + bias          # Mul+Add
        y = jax.nn.relu(y)                                  # Max
        y = y / 0.1                                         # out-quant Div
        return jnp.clip(jnp.round(y), 0, 15).astype(jnp.int8)

    thr = jnp.asarray(np.sort(rng.integers(-3000, 3000, (15, 512)), 0),
                      jnp.int32)

    def fused(acc):
        return ref.multithreshold_ref(acc, thr)

    us_c = _timeit(lambda: jax.jit(composite)(acc))
    us_f = _timeit(lambda: jax.jit(fused)(acc))
    rows.append(("layer_tail_composite", us_c, "passes=5_ops"))
    rows.append(("layer_tail_thresholding", us_f,
                 f"passes=1;speedup_vs_composite={us_c / us_f:.2f}x"))
    return rows
