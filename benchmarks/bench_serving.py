"""Serving throughput: continuous batching + paged KV cache vs the
static-batch engine, fp vs SIRA-derived int8 cache, speculative decoding
on repetitive prompts, copy-on-write prefix caching on repeated system
prompts, and an open-loop Poisson load sweep.

For each batch-slot count: serve a queue of mixed-length requests
(deeper than the slot count) through

  * ``static``     — the pre-scheduler fixed-batch engine (waves of
                     ``batch_slots``, every slot runs to the wave's max
                     new tokens, one jitted call per prompt token);
  * ``paged-fp``   — continuous batching, chunked jitted prefill, full-
                     precision paged cache;
  * ``paged-int8`` — same scheduler, int8 paged cache with per-layer/
                     per-head scales from SIRA range analysis.

Then the ``spec`` pair: a queue of *repetitive* prompts (where prompt-
lookup drafting accepts) through the int8 cache with and without the
n-gram drafter — same tokens, fewer jitted decode steps:

  * ``paged-int8-rep``  — per-token decode on the repetitive queue;
  * ``paged-int8-spec`` — ``spec_decode="ngram"``; records acceptance
                          rate, tokens/decode-step and the tokens/s
                          speedup over the per-token row.

Then the prefix-cache pair (``prefix-fp`` / ``prefix-int8``): a
repeated-system-prompt workload served sequentially — one cold request
that prefills and registers the shared prefix, then warm repeats that
attach it and recompute only the divergent tail.  Emits the warm hit
rate and the cold/warm TTFT speedup (both gated as hard floors in
``check_bench.py``) and asserts the warm outputs are bit-identical to
unshared solo serving.

Finally ``poisson-int8``: an open-loop load generator — Poisson
arrivals of the same repeated-system-prompt traffic against a
``--load-slots``-wide engine with prefix caching on, reporting p50/p99
TTFT, p50/p99 inter-token latency, prefix hit rate and shared-pool
occupancy under load.

    PYTHONPATH=src python benchmarks/bench_serving.py \
        [--slots 2 4] [--requests 12] [--load-slots 32] [--quick] \
        [--out BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def make_requests(cfg, n: int, seed: int = 0):
    from repro.serve import Request

    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab,
                                        size=(int(rng.integers(4, 13)),)),
                    max_new_tokens=int(rng.integers(4, 25)))
            for _ in range(n)]


def make_repetitive_requests(cfg, n: int, seed: int = 0):
    """Prompts that repeat a short pattern — the regime where prompt-
    lookup speculative decoding accepts (summaries, code edits, RAG)."""
    from repro.serve import Request

    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        pat = rng.integers(0, cfg.vocab, size=(int(rng.integers(3, 6)),))
        reqs.append(Request(prompt=np.tile(pat, int(rng.integers(2, 4))),
                            max_new_tokens=int(rng.integers(12, 25))))
    return reqs


def make_prefix_requests(cfg, n: int, sys_len: int = 120,
                         suffix_len: int = 4, max_new: int = 4,
                         seed: int = 0):
    """Repeated-system-prompt traffic: every request shares a ``sys_len``
    token system prompt plus a short unique user suffix — the regime
    prefix caching targets.  The defaults align divergence with a page
    boundary (120 = 15 full pages of 8), so a warm attach is pure
    host-side refcount bookkeeping with zero device copies: cold prefill
    runs 15 chunks, warm prefill 1.  (Mid-page divergence — the
    copy-on-write fork path — is covered by tests, not gated here.)
    Requests are pinned to ``request_id=i`` so the same streams can be
    reproduced by solo serving."""
    from repro.serve import Request

    rng = np.random.default_rng(seed)
    system = rng.integers(0, cfg.vocab, size=(sys_len,))
    return [Request(prompt=np.concatenate(
                        [system, rng.integers(0, cfg.vocab,
                                              size=(suffix_len,))]),
                    max_new_tokens=max_new, request_id=i)
            for i in range(n)]


def bench_static(model, params, reqs, slots: int, max_seq: int) -> dict:
    from repro.serve import Request, ServingConfig, ServingEngine

    eng = ServingEngine(model, params,
                        ServingConfig(batch_slots=slots, max_seq=max_seq,
                                      mode="static"))
    eng.generate([Request(prompt=np.asarray([1, 2, 3]),
                          max_new_tokens=2)])          # jit warm-up
    t0 = time.perf_counter()
    outs = []
    for i in range(0, len(reqs), slots):               # waves
        outs.extend(eng.generate(reqs[i:i + slots]))
    dt = time.perf_counter() - t0
    toks = sum(len(o) for o in outs)
    return dict(engine="static", tokens=toks, seconds=dt,
                tokens_per_s=toks / dt, mean_ttft_s=None,
                slot_occupancy=None, kv_hbm_bytes=None)


def bench_paged(model, params, reqs, slots: int, max_seq: int,
                kv_cache, label: str, spec_decode=None,
                spec_k: int = 4) -> dict:
    from repro.serve import Request, ServingConfig, ServingEngine

    eng = ServingEngine(model, params,
                        ServingConfig(batch_slots=slots, max_seq=max_seq,
                                      kv_cache=kv_cache,
                                      spec_decode=spec_decode,
                                      spec_k=spec_k))
    eng.generate([Request(prompt=np.asarray([1, 2, 3, 1, 2, 3]),
                          max_new_tokens=4)])          # jit warm-up
    eng.reset_metrics()
    t0 = time.perf_counter()
    outs = eng.generate(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(o) for o in outs)
    m = eng.metrics.summary()
    return dict(engine=label, tokens=toks, seconds=dt,
                tokens_per_s=toks / dt, mean_ttft_s=m["mean_ttft_s"],
                slot_occupancy=m["slot_occupancy"],
                kv_hbm_bytes=eng.cache.hbm_bytes(),
                int8_layers=eng.kv_spec.n_int8,
                decode_steps=m["decode_steps"],
                acceptance_rate=m["acceptance_rate"],
                tokens_per_decode_step=m["tokens_per_decode_step"])


def bench_prefix(model, params, cfg, n: int, kv_cache,
                 label: str, max_seq: int = 128) -> dict:
    """Sequential closed-loop repeat-prefix workload: cold request, then
    ``n`` warm repeats served one at a time.  Warm outputs are asserted
    bit-identical to unshared solo serving on a prefix-cache-off engine.

    Runs at ``max_seq=128`` regardless of ``--max-seq`` — the 124-token
    prompt is what makes cold prefill (16 chunks) vs warm attach-and-
    recompute (1 chunk) a meaningful TTFT comparison."""
    from repro.serve import ServingConfig, ServingEngine

    reqs = make_prefix_requests(cfg, n + 1)
    # headroom beyond the worst-case active set so the reuse LRU (shared
    # chain + one divergent page per request) never gets reclaimed
    # mid-benchmark — reclamation order is deterministic but would eat
    # into the hit rate this row gates
    pool = 2 * (-(-max_seq // 8)) + 1 + 20 + n
    eng = ServingEngine(model, params,
                        ServingConfig(batch_slots=2, max_seq=max_seq,
                                      kv_cache=kv_cache,
                                      num_pages=pool,
                                      prefix_cache=True))
    solo = ServingEngine(model, params,
                         ServingConfig(batch_slots=2, max_seq=max_seq,
                                       kv_cache=kv_cache))
    eng.generate([reqs[0]])                            # jit warm-up + cold
    eng.generate([reqs[0]])     # warm re-serve: caches warm-attach path
    eng.reset_metrics()
    # fresh engine state for the measured cold request: a second engine
    # would re-trace, so re-measure the *same* shapes on a cleared cache
    cold_eng = ServingEngine(model, params,
                             ServingConfig(batch_slots=2, max_seq=max_seq,
                                           kv_cache=kv_cache))
    cold_eng.generate([reqs[0]])                       # jit warm-up
    cold_eng.reset_metrics()
    cold_eng.generate([reqs[0]])
    cold_ttft = cold_eng.metrics.mean_ttft

    warm_outs = [eng.generate([r])[0] for r in reqs[1:]]
    m = eng.metrics
    warm_ttft = m.mean_ttft
    for r, out in zip(reqs[1:], warm_outs):
        ref = solo.generate([r])[0]
        assert out == ref, \
            f"prefix-cached output diverged from solo serving ({label})"
    return dict(engine=label, tokens=sum(len(o) for o in warm_outs),
                requests_warm=n,
                cold_ttft_s=cold_ttft, mean_ttft_s=warm_ttft,
                prefix_ttft_speedup=cold_ttft / warm_ttft,
                prefix_hit_rate=m.prefix_hit_rate,
                prefix_forks=eng.cache.forks,
                cached_pages=eng.cache.cached_pages,
                shared_pool_occupancy=eng.cache.shared_pool_occupancy,
                int8_layers=eng.kv_spec.n_int8)


def bench_poisson(model, params, cfg, n: int, slots: int,
                  kv_cache, rate_hz: float, label: str,
                  max_seq: int = 128, seed: int = 0) -> dict:
    """Open-loop load generator: requests arrive on a Poisson clock
    (exponential interarrivals at ``rate_hz``) regardless of engine
    progress — TTFT percentiles therefore include queue wait, which is
    the number a serving fleet is actually sized by."""
    from repro.serve import ServingConfig, ServingEngine

    reqs = make_prefix_requests(cfg, n)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=n)
    gaps[0] = 0.0                                      # first at t=0
    arrivals = np.cumsum(gaps)

    pool = slots * (-(-max_seq // 8)) + 1 + 20 + n      # LRU headroom
    eng = ServingEngine(model, params,
                        ServingConfig(batch_slots=slots, max_seq=max_seq,
                                      kv_cache=kv_cache,
                                      num_pages=pool,
                                      prefix_cache=True))
    # warm-up also registers the shared prefix (and the second serve
    # compiles the attach page-copy ops): the measured open-loop run is
    # pure repeat traffic, the regime the hit-rate floor gates
    eng.generate([reqs[0]])
    eng.generate([reqs[0]])
    eng.reset_metrics()
    eng.cache.forks = 0

    t0 = time.perf_counter()
    nxt = 0
    while nxt < n or eng.scheduler.has_work():
        now = time.perf_counter() - t0
        while nxt < n and arrivals[nxt] <= now:
            eng.submit(reqs[nxt])
            nxt += 1
        if not eng.step() and nxt < n:
            time.sleep(min(arrivals[nxt] - now, 1e-3))
    dt = time.perf_counter() - t0
    eng.run()

    m = eng.metrics
    toks = m.total_tokens
    return dict(engine=label, tokens=toks, seconds=dt,
                tokens_per_s=toks / dt,
                requests_load=n, arrival_rate_hz=rate_hz,
                mean_ttft_s=m.mean_ttft,
                p50_ttft_s=m.ttft_percentile(50),
                p99_ttft_s=m.ttft_percentile(99),
                p50_token_latency_s=m.token_latency_percentile(50),
                p99_token_latency_s=m.token_latency_percentile(99),
                prefix_hit_rate=m.prefix_hit_rate,
                prefix_forks=eng.cache.forks,
                cached_pages=eng.cache.cached_pages,
                shared_pool_occupancy=eng.cache.shared_pool_occupancy,
                preemptions=m.preemptions,
                int8_layers=eng.kv_spec.n_int8)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--load-slots", type=int, default=32,
                    help="batch slots for the open-loop Poisson row")
    ap.add_argument("--load-requests", type=int, default=32)
    ap.add_argument("--poisson-rate", type=float, default=40.0,
                    help="open-loop arrival rate, requests/s")
    ap.add_argument("--prefix-requests", type=int, default=8,
                    help="warm repeats in the prefix-cache rows")
    ap.add_argument("--quick", action="store_true",
                    help="single slot count, fewer requests (CI smoke)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    if args.quick:
        args.slots, args.requests = [2], 6
        args.load_slots, args.load_requests = 16, 16
        args.prefix_requests = 4

    import jax

    from repro.configs import get_config
    from repro.models import get_model
    from repro.serve import derive_kv_spec

    cfg = get_config("qwen2-1.5b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec8 = derive_kv_spec(model, params)

    def _denan(row):
        return {k: (None if isinstance(v, float) and v != v else v)
                for k, v in row.items()}

    results = []
    for slots in args.slots:
        reqs = make_requests(cfg, args.requests)
        if spec8.n_int8 == 0:
            print("WARNING: derive_kv_spec fell back to fp on every "
                  "layer — the paged-int8 row measures an fp cache")
        rows = [
            bench_static(model, params, reqs, slots, args.max_seq),
            bench_paged(model, params, make_requests(cfg, args.requests),
                        slots, args.max_seq, "fp", "paged-fp"),
            bench_paged(model, params, make_requests(cfg, args.requests),
                        slots, args.max_seq, spec8, "paged-int8"),
        ]
        static_tps = rows[0]["tokens_per_s"]
        for r in rows:
            r.update(batch_slots=slots, requests=args.requests,
                     speedup_vs_static=r["tokens_per_s"] / static_tps)
            results.append(_denan(r))
            ttft = (f"ttft={r['mean_ttft_s'] * 1e3:7.1f}ms"
                    if r["mean_ttft_s"] is not None else "ttft=      n/a")
            occ = (f"occ={r['slot_occupancy']:.2f}"
                   if r["slot_occupancy"] is not None else "occ= n/a")
            print(f"slots={slots} {r['engine']:10s} "
                  f"{r['tokens_per_s']:7.1f} tok/s "
                  f"({r['speedup_vs_static']:4.1f}x static) {ttft} {occ}",
                  flush=True)

        # spec pair: same repetitive queue, per-token vs n-gram drafter
        rep = bench_paged(model, params,
                          make_repetitive_requests(cfg, args.requests),
                          slots, args.max_seq, spec8, "paged-int8-rep")
        spec = bench_paged(model, params,
                           make_repetitive_requests(cfg, args.requests),
                           slots, args.max_seq, spec8, "paged-int8-spec",
                           spec_decode="ngram", spec_k=4)
        assert spec["tokens"] == rep["tokens"], \
            "speculative decoding changed the emitted tokens"
        for r in (rep, spec):
            r.update(batch_slots=slots, requests=args.requests,
                     speedup_vs_static=None,
                     speedup_vs_per_token=r["tokens_per_s"]
                     / rep["tokens_per_s"])
            results.append(_denan(r))
            acc = (f"accept={r['acceptance_rate']:.2f}"
                   if r["acceptance_rate"] == r["acceptance_rate"]
                   else "accept= n/a")
            print(f"slots={slots} {r['engine']:15s} "
                  f"{r['tokens_per_s']:7.1f} tok/s "
                  f"({r['speedup_vs_per_token']:4.1f}x per-token) {acc} "
                  f"tok/step={r['tokens_per_decode_step']:.2f} "
                  f"decode_steps={r['decode_steps']}", flush=True)

    # prefix-cache pair: cold vs warm TTFT, bit-identical to solo
    for kv, label in (("fp", "prefix-fp"), (spec8, "prefix-int8")):
        r = bench_prefix(model, params, cfg, args.prefix_requests,
                         kv, label)
        r.update(batch_slots=2)
        results.append(_denan(r))
        print(f"{r['engine']:12s} cold_ttft={r['cold_ttft_s'] * 1e3:6.1f}ms "
              f"warm_ttft={r['mean_ttft_s'] * 1e3:6.1f}ms "
              f"({r['prefix_ttft_speedup']:4.1f}x) "
              f"hit={r['prefix_hit_rate']:.3f} forks={r['prefix_forks']} "
              f"cached={r['cached_pages']}pg", flush=True)

    # open-loop Poisson load: TTFT/latency percentiles under arrival
    # pressure, wide batch, prefix cache on
    r = bench_poisson(model, params, cfg, args.load_requests,
                      args.load_slots, spec8,
                      args.poisson_rate, "poisson-int8")
    r.update(batch_slots=args.load_slots)
    results.append(_denan(r))
    print(f"{r['engine']:12s} slots={args.load_slots} "
          f"rate={args.poisson_rate:g}/s "
          f"p50_ttft={r['p50_ttft_s'] * 1e3:6.1f}ms "
          f"p99_ttft={r['p99_ttft_s'] * 1e3:6.1f}ms "
          f"p50_tok={r['p50_token_latency_s'] * 1e3:5.1f}ms "
          f"p99_tok={r['p99_token_latency_s'] * 1e3:5.1f}ms "
          f"hit={r['prefix_hit_rate']:.3f} occ={r['shared_pool_occupancy']:.3f}",
          flush=True)

    payload = dict(backend=jax.default_backend(),
                   arch=cfg.name, requests=args.requests,
                   load_slots=args.load_slots,
                   load_requests=args.load_requests,
                   int8_layers=f"{spec8.n_int8}/{len(spec8.layers)}",
                   results=results)
    from repro.obs.metrics import export_bench
    export_bench(payload, args.out, key=("engine", "batch_slots"))
    print(f"wrote {args.out} (+ Prometheus text next to it)")


if __name__ == "__main__":
    main()
