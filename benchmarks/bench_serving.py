"""Serving throughput: continuous batching + paged KV cache vs the
static-batch engine, fp vs SIRA-derived int8 cache, plus speculative
decoding on repetitive prompts.

For each batch-slot count: serve a queue of mixed-length requests
(deeper than the slot count) through

  * ``static``     — the pre-scheduler fixed-batch engine (waves of
                     ``batch_slots``, every slot runs to the wave's max
                     new tokens, one jitted call per prompt token);
  * ``paged-fp``   — continuous batching, chunked jitted prefill, full-
                     precision paged cache;
  * ``paged-int8`` — same scheduler, int8 paged cache with per-layer/
                     per-head scales from SIRA range analysis.

Then the ``spec`` pair: a queue of *repetitive* prompts (where prompt-
lookup drafting accepts) through the int8 cache with and without the
n-gram drafter — same tokens, fewer jitted decode steps:

  * ``paged-int8-rep``  — per-token decode on the repetitive queue;
  * ``paged-int8-spec`` — ``spec_decode="ngram"``; records acceptance
                          rate, tokens/decode-step and the tokens/s
                          speedup over the per-token row.

Records tokens/s, mean TTFT (paged modes), slot occupancy, KV HBM bytes,
and the paged-over-static speedup.

    PYTHONPATH=src python benchmarks/bench_serving.py \
        [--slots 2 4] [--requests 12] [--quick] [--out BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def make_requests(cfg, n: int, seed: int = 0):
    from repro.serve import Request

    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab,
                                        size=(int(rng.integers(4, 13)),)),
                    max_new_tokens=int(rng.integers(4, 25)))
            for _ in range(n)]


def make_repetitive_requests(cfg, n: int, seed: int = 0):
    """Prompts that repeat a short pattern — the regime where prompt-
    lookup speculative decoding accepts (summaries, code edits, RAG)."""
    from repro.serve import Request

    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        pat = rng.integers(0, cfg.vocab, size=(int(rng.integers(3, 6)),))
        reqs.append(Request(prompt=np.tile(pat, int(rng.integers(2, 4))),
                            max_new_tokens=int(rng.integers(12, 25))))
    return reqs


def bench_static(model, params, reqs, slots: int, max_seq: int) -> dict:
    from repro.serve import Request, ServingEngine

    eng = ServingEngine(model, params, batch_slots=slots, max_seq=max_seq,
                        mode="static")
    eng.generate([Request(prompt=np.asarray([1, 2, 3]),
                          max_new_tokens=2)])          # jit warm-up
    t0 = time.perf_counter()
    outs = []
    for i in range(0, len(reqs), slots):               # waves
        outs.extend(eng.generate(reqs[i:i + slots]))
    dt = time.perf_counter() - t0
    toks = sum(len(o) for o in outs)
    return dict(engine="static", tokens=toks, seconds=dt,
                tokens_per_s=toks / dt, mean_ttft_s=None,
                slot_occupancy=None, kv_hbm_bytes=None)


def bench_paged(model, params, reqs, slots: int, max_seq: int,
                kv_cache, label: str, spec_decode=None,
                spec_k: int = 4) -> dict:
    from repro.serve import Request, ServingEngine

    eng = ServingEngine(model, params, batch_slots=slots, max_seq=max_seq,
                        kv_cache=kv_cache, spec_decode=spec_decode,
                        spec_k=spec_k)
    eng.generate([Request(prompt=np.asarray([1, 2, 3, 1, 2, 3]),
                          max_new_tokens=4)])          # jit warm-up
    eng.reset_metrics()
    t0 = time.perf_counter()
    outs = eng.generate(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(o) for o in outs)
    m = eng.metrics.summary()
    return dict(engine=label, tokens=toks, seconds=dt,
                tokens_per_s=toks / dt, mean_ttft_s=m["mean_ttft_s"],
                slot_occupancy=m["slot_occupancy"],
                kv_hbm_bytes=eng.cache.hbm_bytes(),
                int8_layers=eng.kv_spec.n_int8,
                decode_steps=m["decode_steps"],
                acceptance_rate=m["acceptance_rate"],
                tokens_per_decode_step=m["tokens_per_decode_step"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--quick", action="store_true",
                    help="single slot count, fewer requests (CI smoke)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    if args.quick:
        args.slots, args.requests = [2], 6

    import jax

    from repro.configs import get_config
    from repro.models import get_model
    from repro.serve import derive_kv_spec

    cfg = get_config("qwen2-1.5b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec8 = derive_kv_spec(model, params)

    def _denan(row):
        return {k: (None if isinstance(v, float) and v != v else v)
                for k, v in row.items()}

    results = []
    for slots in args.slots:
        reqs = make_requests(cfg, args.requests)
        if spec8.n_int8 == 0:
            print("WARNING: derive_kv_spec fell back to fp on every "
                  "layer — the paged-int8 row measures an fp cache")
        rows = [
            bench_static(model, params, reqs, slots, args.max_seq),
            bench_paged(model, params, make_requests(cfg, args.requests),
                        slots, args.max_seq, "fp", "paged-fp"),
            bench_paged(model, params, make_requests(cfg, args.requests),
                        slots, args.max_seq, spec8, "paged-int8"),
        ]
        static_tps = rows[0]["tokens_per_s"]
        for r in rows:
            r.update(batch_slots=slots, requests=args.requests,
                     speedup_vs_static=r["tokens_per_s"] / static_tps)
            results.append(_denan(r))
            ttft = (f"ttft={r['mean_ttft_s'] * 1e3:7.1f}ms"
                    if r["mean_ttft_s"] is not None else "ttft=      n/a")
            occ = (f"occ={r['slot_occupancy']:.2f}"
                   if r["slot_occupancy"] is not None else "occ= n/a")
            print(f"slots={slots} {r['engine']:10s} "
                  f"{r['tokens_per_s']:7.1f} tok/s "
                  f"({r['speedup_vs_static']:4.1f}x static) {ttft} {occ}",
                  flush=True)

        # spec pair: same repetitive queue, per-token vs n-gram drafter
        rep = bench_paged(model, params,
                          make_repetitive_requests(cfg, args.requests),
                          slots, args.max_seq, spec8, "paged-int8-rep")
        spec = bench_paged(model, params,
                           make_repetitive_requests(cfg, args.requests),
                           slots, args.max_seq, spec8, "paged-int8-spec",
                           spec_decode="ngram", spec_k=4)
        assert spec["tokens"] == rep["tokens"], \
            "speculative decoding changed the emitted tokens"
        for r in (rep, spec):
            r.update(batch_slots=slots, requests=args.requests,
                     speedup_vs_static=None,
                     speedup_vs_per_token=r["tokens_per_s"]
                     / rep["tokens_per_s"])
            results.append(_denan(r))
            acc = (f"accept={r['acceptance_rate']:.2f}"
                   if r["acceptance_rate"] == r["acceptance_rate"]
                   else "accept= n/a")
            print(f"slots={slots} {r['engine']:15s} "
                  f"{r['tokens_per_s']:7.1f} tok/s "
                  f"({r['speedup_vs_per_token']:4.1f}x per-token) {acc} "
                  f"tok/step={r['tokens_per_decode_step']:.2f} "
                  f"decode_steps={r['decode_steps']}", flush=True)

    payload = dict(backend=jax.default_backend(),
                   arch=cfg.name, requests=args.requests,
                   int8_layers=f"{spec8.n_int8}/{len(spec8.layers)}",
                   results=results)
    from repro.obs.metrics import export_bench
    export_bench(payload, args.out, key=("engine", "batch_slots"))
    print(f"wrote {args.out} (+ Prometheus text next to it)")


if __name__ == "__main__":
    main()
